"""Fused vs host-loop HSFL round benchmark (the fig. 3 hot path).

Measures rounds/sec of ``HSFLSimulation.run_round`` at the paper's scale
(30 UAVs, K=10 selected, e=6 local epochs, b=2, OPT scheme) for:

  host          — the original Python control loop over OppTransmitter
  fused         — the single-jit device round (core/fused_round) on the
                  default forward policy: the pool-first custom-VJP
                  training step (kernels/fused_cnn) + donated round
                  carries.  ``--kernel``/``--precision`` reroute it.
  fused_im2col  — the same round on the PR-1 step (forward_im2col +
                  autodiff, no donation-relevant change): the compute
                  floor the custom-VJP step is measured against,
                  *within the same run*
  fused_bf16    — default kernel at precision=bf16 (mixed precision; on
                  CPU bf16 is emulated, so this row is a numerics
                  regression canary, not a speed win — on TPU it is the
                  point)
  fused_pallas  — the Pallas kernel suite; interpret mode off-TPU (value
                  pin + smoke, expect it slower on CPU)
  fused_sharded — default policy, with the stacked-user axis sharded over
                  N forced host devices (bench-only XLA_FLAGS subprocess)
  fused_codec   — fused with int8 delta-codec snapshots

plus the PR-2 *grid* engines, which time the whole Fig. 3(b) panel
(3 schemes × ``--grid-seeds`` seeds) instead of one round:

  grid_loop     — one **cold** ``run_hsfl`` per (scheme, seed) cell,
                  exactly what ``paper_experiments._run`` pays: dataset/sim
                  setup and fresh jit compiles per cell are inherent to the
                  loop engine (every ``HSFLSimulation`` builds new
                  closures) and are included in its wall
  grid_sweep    — the vectorized sweep engine (core/sweep): rounds scanned,
                  seeds vmapped (sharded over forced host devices in the
                  *_sharded* variant), channel realized on-device;
                  ``wall_s`` is end-to-end with compiles, with
                  ``steady_wall_s``/``compile_s`` split out since its per-
                  scheme programs are compiled once and reusable
  grid_sweep_codec — the same panel with int8 delta-codec snapshots
                  (``use_delta_codec``): compiles opt-codec + async only —
                  discard lowers onto the opt program at b=1
                  (``compiled_programs`` records the count)

Methodology: each engine runs in its own subprocess (so XLA device forcing
can't leak); per engine we run ``--warmup`` rounds first on the same
simulation instance so every K-bucket jit variant is compiled, then time
``--rounds`` rounds and report the mean.  Exception: the ``fused`` vs
``fused_im2col`` comparison is measured *interleaved in one process*
(round of one, round of the other, repeated): the bench container's
throughput swings ±50% minute to minute, so sequential subprocesses
minutes apart cannot resolve the 3–30% step-level delta — those two rows
carry ``"paired": true``.  Results append to BENCH_hsfl.json.

``--scheme`` runs the single-round engines under any *registered*
transmission scheme (the ``repro.core.schemes`` registry — the choices
list is dynamic, so a newly registered scheme is immediately benchable);
every row records its ``scheme`` label.

  PYTHONPATH=src python -m benchmarks.hsfl_round_bench
  PYTHONPATH=src python -m benchmarks.hsfl_round_bench --rounds 20 --devices 2
  PYTHONPATH=src python -m benchmarks.hsfl_round_bench --scheme deadline
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


ENGINES = ("host", "fused", "fused_im2col", "fused_bf16", "fused_pallas",
           "fused_codec", "fused_sharded",
           "grid_loop", "grid_sweep", "grid_sweep_codec")

# engine name -> forward-policy override (None = use the CLI flags)
ENGINE_POLICY = {"fused_im2col": ("im2col", "f32"),
                 "fused_bf16": (None, "bf16"),
                 "fused_pallas": ("pallas", None)}


def measure_grid(engine: str, rounds: int, seeds: int) -> dict:
    """Wall-clock the whole fig3b grid: 3 schemes × seeds × rounds.

    ``grid_loop`` is exactly what ``paper_experiments._run`` does — a cold
    ``run_hsfl`` per (scheme, seed) cell, each paying dataset/sim setup and
    fresh jit compiles (the loop engine cannot amortize them across cells:
    every ``HSFLSimulation`` builds new closures).  ``grid_sweep`` reports
    the same end-to-end wall (``wall_s``, compiles included) plus the
    steady-state re-execution wall (``steady_wall_s``) and ``compile_s``
    separately, since the sweep's three programs are compiled once and
    reused for any number of seeds/configs/rounds.
    """
    import time

    import jax

    combos = (("opt", 2), ("async", 1), ("discard", 1))
    seed_list = tuple(range(seeds))
    base = dict(devices=len(jax.devices()), grid="fig3b",
                schemes=[s for s, _ in combos],
                sims=len(combos) * seeds, rounds_timed=rounds)

    if engine == "grid_loop":
        from repro.api import Experiment
        t0 = time.time()
        for scheme, b in combos:
            for sd in seed_list:
                (Experiment(scheme=scheme, b=b, seed=sd, rounds=rounds)
                 .run(engine="fused"))
        wall = time.time() - t0
        return dict(base, engine=engine, wall_s=round(wall, 2),
                    sim_rounds_per_sec=round(base["sims"] * rounds / wall, 3))

    from repro.api import Experiment
    from repro.core.sweep import fig3b_spec
    # grid_sweep_codec: the same fig3b panel with int8 delta-codec
    # snapshots — opt-codec + async compile; discard lowers onto opt@b=1
    spec = fig3b_spec(rounds, seed_list,
                      use_delta_codec=engine == "grid_sweep_codec")[0]
    res = Experiment.from_spec(spec).run(engine="sweep", timeit=True)
    steady = sum(g.run_s for g in res.groups)
    compile_s = sum(g.compile_s for g in res.groups)
    # background AOT compiles overlap execution, so the critical-path wall
    # is the compile total minus what was hidden behind running groups
    wall = steady + compile_s - res.compile_overlap_s
    return dict(base, engine=engine, wall_s=round(wall, 2),
                steady_wall_s=round(steady, 2),
                compile_s=round(compile_s, 2),
                compile_overlap_s=round(res.compile_overlap_s, 2),
                compiled_programs=res.n_programs,
                sim_rounds_per_sec=round(base["sims"] * rounds / steady, 3))


def measure_pair(warmup: int, rounds: int, kernel: str = "xla",
                 precision: str = "f32", scheme: str = "opt") -> dict:
    """Interleave the policy-selected fused engine (``--kernel``/
    ``--precision``; default the custom-VJP xla/f32 step) against the PR-1
    autodiff baseline (kernel=im2col) round by round in ONE process, so
    both see the same container throttling — the only way this box can
    resolve their delta (see module docstring)."""
    import time

    import jax

    from repro.core.hsfl import HSFLConfig, HSFLSimulation

    pair = {"fused": (kernel, precision), "fused_im2col": ("im2col", "f32")}
    sims, state = {}, {}
    for name, (kern, prec) in pair.items():
        cfg = HSFLConfig(scheme=scheme, b=2, rounds=warmup + rounds,
                         kernel=kern, precision=prec)
        sims[name] = HSFLSimulation(cfg)
        state[name] = ([], 1)
    for name, sim in sims.items():
        delayed, t = state[name]
        for _ in range(warmup):
            _, delayed = sim.run_round(t, delayed)
            t += 1
        jax.block_until_ready(sim.params)
        state[name] = (delayed, t)
    tot = {k: 0.0 for k in sims}
    sel = {k: 0 for k in sims}
    for _ in range(rounds):
        for name, sim in sims.items():
            delayed, t = state[name]
            t0 = time.time()
            log, delayed = sim.run_round(t, delayed)
            jax.block_until_ready(sim.params)
            tot[name] += time.time() - t0
            sel[name] += log.selected
            state[name] = (delayed, t + 1)
    rows = []
    for name, (kern, prec) in pair.items():
        ms = tot[name] / rounds * 1e3
        rows.append({"engine": name, "ms_per_round": round(ms, 1),
                     "rounds_per_sec": round(1e3 / ms, 3),
                     "mean_selected": round(sel[name] / rounds, 1),
                     "scheme": scheme, "kernel": kern, "precision": prec,
                     "paired": True, "devices": len(jax.devices())})
    return {"engine": "fused_pair", "rows": rows}


def measure(engine: str, warmup: int, rounds: int,
            kernel: str = "xla", precision: str = "f32",
            scheme: str = "opt") -> dict:
    import time

    import jax

    from repro.core.hsfl import HSFLConfig, HSFLSimulation

    if engine not in ENGINES:
        raise SystemExit(f"unknown engine {engine!r}; choose from {ENGINES}")
    k_over, p_over = ENGINE_POLICY.get(engine, (None, None))
    cfg = HSFLConfig(scheme=scheme, b=2, rounds=warmup + rounds,
                     use_fused_round=engine != "host",
                     use_delta_codec=engine == "fused_codec",
                     kernel=k_over or kernel, precision=p_over or precision)
    sim = HSFLSimulation(cfg)
    delayed, t = [], 1
    for _ in range(warmup):
        log, delayed = sim.run_round(t, delayed)
        t += 1
    jax.block_until_ready(sim.params)
    t0 = time.time()
    selected = 0
    for _ in range(rounds):
        log, delayed = sim.run_round(t, delayed)
        selected += log.selected
        t += 1
    jax.block_until_ready(sim.params)
    ms = (time.time() - t0) / rounds * 1e3
    return {"engine": engine, "ms_per_round": round(ms, 1),
            "rounds_per_sec": round(1e3 / ms, 3),
            "mean_selected": round(selected / rounds, 1),
            "scheme": cfg.scheme, "kernel": cfg.kernel,
            "precision": cfg.precision,
            "devices": len(jax.devices())}


def run_child(engine: str, args, devices: int = 1, tag: str = "",
              rounds: int | None = None, warmup: int | None = None) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    if devices > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={devices}")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.hsfl_round_bench",
         "--engine", engine,
         "--warmup", str(args.warmup if warmup is None else warmup),
         "--rounds", str(args.rounds if rounds is None else rounds),
         "--kernel", args.kernel, "--precision", args.precision,
         "--scheme", args.scheme,
         "--grid-rounds", str(args.grid_rounds),
         "--grid-seeds", str(args.grid_seeds)],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    if out.returncode != 0:
        raise RuntimeError(f"{engine} failed:\n{out.stdout}\n{out.stderr}")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    name = tag or engine
    if "rows" in rec:
        for row in rec["rows"]:
            print(f"{row['engine']:18s} {row['ms_per_round']:8.1f} ms/round "
                  f"({row['rounds_per_sec']:.3f} rounds/s, paired)")
        return rec
    rec["engine"] = name
    if "ms_per_round" in rec:
        print(f"{name:18s} {rec['ms_per_round']:8.1f} ms/round "
              f"({rec['rounds_per_sec']:.3f} rounds/s, "
              f"devices={rec['devices']})")
    else:
        print(f"{name:18s} {rec['wall_s']:8.2f} s grid "
              f"({rec['sim_rounds_per_sec']:.3f} sim-rounds/s, "
              f"sims={rec['sims']}, devices={rec['devices']})")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--devices", type=int, default=2,
                    help="forced host devices for the sharded variants")
    ap.add_argument("--grid-rounds", type=int, default=8,
                    help="rounds per simulation for the fig3b grid engines")
    ap.add_argument("--grid-seeds", type=int, default=2,
                    help="seeds per scheme for the fig3b grid engines")
    ap.add_argument("--skip-grid", action="store_true",
                    help="only run the single-round engines")
    ap.add_argument("--kernel", default="xla",
                    choices=["xla", "pallas", "im2col"],
                    help="forward policy for the default fused engine "
                         "(kernels/fused_cnn.ForwardPolicy)")
    ap.add_argument("--precision", default="f32", choices=["f32", "bf16"],
                    help="compute precision for the default fused engine")
    from repro.core.schemes import registered_schemes
    ap.add_argument("--scheme", default="opt", choices=registered_schemes(),
                    help="transmission scheme for the single-round engines "
                         "(any registered repro.core.schemes name); "
                         "recorded per row in BENCH_hsfl.json")
    ap.add_argument("--skip-policy-rows", action="store_true",
                    help="skip the fused_im2col/fused_bf16/fused_pallas "
                         "policy comparison rows")
    ap.add_argument("--out", default="BENCH_hsfl.json")
    ap.add_argument("--engine", default=None,
                    help="(internal) measure one engine and print JSON")
    args = ap.parse_args()

    if args.engine:
        if args.engine.startswith("grid_"):
            rec = measure_grid(args.engine, args.grid_rounds,
                               args.grid_seeds)
        elif args.engine == "fused_pair":
            rec = measure_pair(args.warmup, args.rounds,
                               kernel=args.kernel, precision=args.precision,
                               scheme=args.scheme)
        else:
            rec = measure(args.engine, args.warmup, args.rounds,
                          kernel=args.kernel, precision=args.precision,
                          scheme=args.scheme)
        print(json.dumps(rec))
        return

    recs = [run_child("host", args)]
    recs += run_child("fused_pair", args)["rows"]
    if not args.skip_policy_rows:
        # bf16 at full length (it is a numerics canary); the interpret-mode
        # Pallas row at reduced length — off-TPU it only pins that the
        # kernel path runs end to end, not its speed
        recs.append(run_child("fused_bf16", args))
        recs.append(run_child("fused_pallas", args,
                              rounds=max(2, args.rounds // 4),
                              warmup=min(2, args.warmup)))
    recs.append(run_child("fused_codec", args))
    if args.devices > 1:
        recs.append(run_child("fused_sharded", args, devices=args.devices))

    by = {r["engine"]: r for r in recs}
    host_ms = by["host"]["ms_per_round"]
    result = {
        "config": {"n_uavs": 30, "k_select": 10, "local_epochs": 6, "b": 2,
                   "scheme": args.scheme, "steps_per_epoch": 4,
                   "batch_size": 10,
                   "rounds_timed": args.rounds, "warmup": args.warmup},
        "engines": recs,
        "speedup_fused_vs_host": round(host_ms / by["fused"]["ms_per_round"],
                                       2),
        # the compute-floor comparison: custom-VJP step vs the PR-1
        # autodiff step, same container, same run
        "speedup_fused_vs_im2col": round(
            by["fused_im2col"]["ms_per_round"] / by["fused"]["ms_per_round"],
            2),
    }
    if args.devices > 1:
        result["speedup_sharded_vs_host"] = round(
            host_ms / by["fused_sharded"]["ms_per_round"], 2)
    print(f"\nspeedup fused vs host: {result['speedup_fused_vs_host']}x")
    print(f"speedup fused (custom-VJP) vs im2col step: "
          f"{result['speedup_fused_vs_im2col']}x")
    if "speedup_sharded_vs_host" in result:
        print(f"speedup sharded vs host: {result['speedup_sharded_vs_host']}x")

    if not args.skip_grid:
        # -- fig3b grid: loop of fused run_hsfl cells vs one sweep program --
        grid = [run_child("grid_loop", args),
                run_child("grid_sweep", args),
                run_child("grid_sweep_codec", args)]
        if args.devices > 1:
            grid.append(run_child("grid_sweep", args, devices=args.devices,
                                  tag="grid_sweep_sharded"))
        loop_w = grid[0]["wall_s"]
        gres = {
            "config": {"grid": "fig3b", "schemes": 3,
                       "seeds": args.grid_seeds,
                       "rounds_timed": args.grid_rounds,
                       "eval_every_round": True},
            "engines": grid,
            "speedup_sweep_vs_loop": round(loop_w / grid[1]["wall_s"], 2),
        }
        if args.devices > 1:
            gres["speedup_sweep_sharded_vs_loop"] = round(
                loop_w / grid[-1]["wall_s"], 2)
        print(f"speedup sweep vs loop (fig3b grid): "
              f"{gres['speedup_sweep_vs_loop']}x")
        if "speedup_sweep_sharded_vs_loop" in gres:
            print(f"speedup sweep sharded vs loop: "
                  f"{gres['speedup_sweep_sharded_vs_loop']}x")
        result["fig3b_grid"] = gres

    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
