"""Roofline table builder — reads dry-run JSONL records (deliverable g).

Usage:
  PYTHONPATH=src python -m benchmarks.roofline results/dryrun_singlepod.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(path: str) -> List[Dict]:
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # keep the latest record per (arch, shape, mesh, opts)
    latest = {}
    for r in recs:
        key = (r["arch"], r["shape"], r.get("multi_pod"),
               json.dumps(r.get("opts", {}), sort_keys=True),
               json.dumps(r.get("overrides", {}), sort_keys=True))
        latest[key] = r
    return list(latest.values())


def markdown_table(recs: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful | mem/dev GiB |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") == "skip_documented":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip (encoder-only) | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | |")
            continue
        if "roofline" not in r:     # --no-calibrate lowering-proof records
            lines.append(f"| {r['arch']} | {r['shape']} | ✓ lowered+compiled "
                         f"| | | | | {per_device_gib(r):.2f} |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['dominant'].replace('_s','')} | {t['useful_ratio']:.2f} | "
            f"{per_device_gib(r):.2f} |")
    return "\n".join(lines)


def per_device_gib(rec: Dict) -> float:
    """argument_size is per-device; temp_size aggregates the host's devices."""
    m = rec.get("memory", {})
    n = rec.get("n_chips", 256)
    return (m.get("argument_size_in_bytes", 0)
            + m.get("temp_size_in_bytes", 0) / n) / 2**30


def csv_rows(recs: List[Dict]) -> List[Dict]:
    rows = []
    for r in recs:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        t = r["roofline"]
        dom = t["dominant"]
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}"
                    + ("_mp" if r.get("multi_pod") else ""),
            "us_per_call": t[dom] * 1e6,          # the binding roofline term
            "dominant": dom,
            "useful_ratio": round(t["useful_ratio"], 3),
        })
    return rows


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_singlepod.jsonl"
    recs = load(path)
    print(markdown_table(recs))
    ok = [r for r in recs if r.get("status") == "ok"]
    fails = [r for r in recs if r.get("status") == "fail"]
    print(f"\n{len(ok)} ok, {len(fails)} failed, "
          f"{sum(r.get('status') == 'skip_documented' for r in recs)} documented skips")
    for r in fails:
        print("FAIL:", r["arch"], r["shape"], r.get("error", "")[:200])


if __name__ == "__main__":
    main()
