"""Kernel-layer micro-benchmarks.

On this CPU-only container the Pallas kernels run in interpret mode (they
TARGET TPU), so wall-clock timings cover the XLA reference paths (what the
dry-run lowers) and interpret-mode parity checks; the kernel's value is
argued via the §Roofline bytes-moved analysis (e.g. wkv6 keeps the (D,D)
state in VMEM — a ~32x HBM-traffic cut vs the XLA scan at D=64).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.wkv6.ref import wkv6_ref
from repro.kernels.delta_codec.ref import dequantize_ref, quantize_ref


def _time(fn, *args, reps: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def bench_attention_ref() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []
    for (BH, S, D) in [(8, 512, 64), (8, 1024, 64)]:
        q, k, v = (jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
                   for _ in range(3))
        f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
        us = _time(f, q, k, v)
        flops = 4 * BH * S * S * D / 2
        rows.append({"name": f"attn_ref_BH{BH}_S{S}", "us_per_call": us,
                     "gflops_s": flops / us / 1e3})
    return rows


def bench_wkv6_ref() -> List[Dict]:
    rng = np.random.default_rng(0)
    B, S, H, D = 1, 512, 4, 64
    r, k, v = (jnp.asarray(rng.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.random((B, S, H, D)) * 0.4 + 0.5, jnp.float32)
    u = jnp.zeros((H, D), jnp.float32)
    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    f = jax.jit(lambda *a: wkv6_ref(*a)[0])
    us = _time(f, r, k, v, w, u, S0)
    # HBM traffic of the scan path (state round trip per step) vs the kernel
    scan_bytes = 2 * 4 * D * D * S * B * H
    kern_bytes = 4 * S * D * B * H * 2
    return [{"name": f"wkv6_ref_S{S}", "us_per_call": us,
             "scan_hbm_bytes": scan_bytes, "kernel_hbm_bytes": kern_bytes,
             "traffic_ratio": scan_bytes / kern_bytes}]


def bench_codec_ref() -> List[Dict]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2048, 512)), jnp.float32)
    f = jax.jit(lambda x: dequantize_ref(*quantize_ref(x)))
    us = _time(f, x)
    return [{"name": "delta_codec_roundtrip_1M", "us_per_call": us,
             "compress_ratio": (1 + 4 / 512) / 4}]


def all_benches() -> List[Dict]:
    return bench_attention_ref() + bench_wkv6_ref() + bench_codec_ref()
